"""falcon-mamba-7b — attention-free Mamba-1 [arXiv:2410.05355]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    vocab=65024, ssm_state=16, ssm_variant="mamba1", expand=2, d_conv=4,
    source="arXiv:2410.05355",
)
